"""Cluster serving runtime benchmark + chaos drill (ISSUE 4 acceptance).

Drives the sharded/replicated/WAL-durable ``ClusterRouter`` (DESIGN.md §7)
through the scenarios the subsystem exists for, and emits machine-readable
``BENCH_cluster.json`` whose acceptance flags CI asserts:

  1. steady-state traffic: S shards x R replicas, mixed batch sizes —
     results bit-identical to the flat single-engine path;
  2. chaos: a replica starts failing unannounced mid-traffic — every query
     still answers (``zero_dropped_queries_under_kill``);
  3. durability: mutations are WAL'd, the dead replica recovers via
     snapshot + WAL replay + peer catch-up, its peer is killed so the
     RECOVERED replica serves, and the answers match the single-engine
     mirror of the same mutation history (``recovery_consistent``);
  4. hedging: a replica is made slow (not dead); the router re-issues past
     the hedge deadline and the fast peer's answer wins
     (``hedged_reissues``/``hedge_wins``);
  5. caching + admission: repeat traffic hits the mutation-signature cache;
     a bounded queue and expired deadlines shed with explicit stats;
  6. multi-process serving (ISSUE 7 / DESIGN.md §10): the same router over
     worker *subprocesses* behind the RPC transport — flat bit-identity
     across the wire, an honest in-process vs multi-process q/s comparison
     (``speedup`` = ``process_qps / inproc_qps``, both measured in THIS
     run at the SAME topology and pipeline depth — never against the
     steady-state ``steady_qps`` above, whose shape differs; the ≥4x gate
     is asserted only where it is physically meaningful: ``cores >= 4 and
     workers >= 4``; the measured speedup, its denominator, and the core
     count are always recorded), and a worker-SIGKILL chaos drill
     (failover + WAL replay + peer catch-up, zero dropped batches);
  7. shm fast path vs socket (DESIGN.md §13): the SAME process topology
     with the slab fast path on (threshold lowered so every payload
     stages) vs off — bit-identity, q/s, and the ``repro.cluster.shm``
     wire-counter deltas over the timed window proving the router paid
     ZERO socket payload bytes in either direction (the ≥1.3x speedup
     gate applies only at ``cores >= 4``; the ratio is always recorded);
  8. tcp vs unix: the multi-host transport on loopback at the same
     topology — flat bit-identity across AF_INET plus the honest q/s
     ratio against the AF_UNIX number from section 6.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster import ClusterConfig, ClusterRouter
from repro.core.index import IndexConfig, build_index, query_index
from repro.data import ann_synthetic as ds
from repro.serve.engine import AnnServingEngine, ServeConfig


def _throughput_qps(router, rows: np.ndarray, batch: int) -> float:
    """q/s over a pre-generated row block, submitted in one go so
    ``pipeline_depth`` can overlap batches (cache must be disabled)."""
    t0 = time.perf_counter()
    router.submit(rows)
    d, i = router.drain()
    dt = time.perf_counter() - t0
    assert d.shape[0] == rows.shape[0], (d.shape, rows.shape)
    # far-from-data random rows may legitimately fill < k neighbors (-1
    # padding), so "nothing dropped" is pinned via the router's explicit
    # failure stats, not per-row sentinels
    s = router.summary()
    assert s["dispatch_failures"] == 0, s
    assert s["rejected_queue_full"] == 0 and s["rejected_deadline"] == 0, s
    return rows.shape[0] / dt


def _multiprocess_section(cfg, serve_cfg, data, queries, fd, fi, workers: int,
                          batch: int, smoke: bool, root: str) -> dict:
    """Section 6: processes vs in-process, identity, and the SIGKILL drill."""
    cores = len(os.sched_getaffinity(0))
    rng = np.random.default_rng(11)
    n_rows = batch * (6 if smoke else 16)
    rows = (rng.integers(0, 32, (n_rows, data.shape[1])) * 2).astype(np.int32)
    key = jax.random.PRNGKey(0)
    depth = 4

    def build(transport, n_shards, n_reps, tag, cache=0):
        return ClusterRouter(
            cfg, serve_cfg,
            ClusterConfig(num_shards=n_shards, num_replicas=n_reps,
                          hedge_ms=60000.0, wal_fsync=False,
                          cache_capacity=cache, transport=transport,
                          pipeline_depth=depth,
                          max_queue_depth=max(4096, n_rows)),
            data, root + tag, key=key)

    # in-process baseline at the SAME topology + pipeline depth: the only
    # variable in the comparison is the process boundary
    inproc = build("inproc", workers, 1, "-mp-in")
    inproc.query(queries[:batch])                   # warm compile paths
    inproc_qps = _throughput_qps(inproc, rows, batch)
    inproc.close()

    t0 = time.perf_counter()
    proc = build("process", workers, 1, "-mp-proc")
    boot_ms = (time.perf_counter() - t0) * 1e3
    pd_, pi = proc.query(queries)
    mp_flat_identity = bool(np.array_equal(pd_, fd)
                            and np.array_equal(pi, fi))
    proc.clear_cache()
    proc_qps = _throughput_qps(proc, rows, batch)
    proc.close()
    speedup = proc_qps / max(inproc_qps, 1e-9)
    # the >=4x acceptance gate only means something where 4x parallelism
    # physically exists; elsewhere the honest numbers are still recorded
    gate_eligible = bool(cores >= 4 and workers >= 4)
    speedup_ok = bool((not gate_eligible) or speedup >= 4.0)

    # SIGKILL chaos drill: S=2 x R=2 worker grid, a worker is SIGKILL'd
    # UNANNOUNCED mid-stream (no router-side markdown first) -> failover;
    # mutations while it is down -> peer acks; recover -> respawn + WAL
    # replay + peer catch-up; peer killed -> the RECOVERED worker serves,
    # matching a single-engine mirror of the same mutation history.
    half = data[: data.shape[0] // 2]
    drill = ClusterRouter(
        cfg, serve_cfg,
        ClusterConfig(num_shards=2, num_replicas=2, hedge_ms=60000.0,
                      wal_fsync=False, cache_capacity=0,
                      transport="process"),
        half, root + "-mp-drill", key=key)
    mirror = AnnServingEngine(cfg, serve_cfg, dataset=jnp.asarray(half),
                              key=key)
    pts = (queries[: queries.shape[0] // 2] + 4).astype(np.int32)
    g_d, g_m = drill.insert(pts), mirror.insert(pts)
    assert np.array_equal(g_d, g_m)
    submitted = answered = 0
    drill_waves = 3
    for wave in range(drill_waves):
        if wave == 1:
            drill.replicas[0][0].handle.sigkill()   # the real thing
        q = (queries + wave).astype(np.int32)
        d, i = drill.query(q)
        submitted += q.shape[0]
        answered += int((i >= 0).all(axis=1).sum())
    mp_zero_dropped = bool(answered == submitted)
    drill.replicas[0][0].alive = False              # router-side markdown
    drill.delete(g_d[::3])                          # mutations while down
    mirror.delete(g_m[::3])
    recov = drill.recover_replica(0, 0)             # respawn + replay
    drill.kill_replica(0, 1)                        # peer dies: recovered serves
    rd, ri = drill.query(queries)
    md, mi = mirror.query_batch(queries)
    mp_recovery_consistent = bool(np.array_equal(rd, md)
                                  and np.array_equal(ri, mi))
    dstats = drill.summary()
    drill.close()
    for tag in ("-mp-in", "-mp-proc", "-mp-drill"):
        shutil.rmtree(root + tag, ignore_errors=True)
    return {
        "workers": workers,
        "cores": cores,
        "pipeline_depth": depth,
        "boot_ms": round(boot_ms, 1),
        "inproc_qps": round(inproc_qps, 1),
        "process_qps": round(proc_qps, 1),
        "speedup": round(speedup, 2),
        "speedup_gate_eligible": gate_eligible,
        "drill": {"submitted": submitted, "answered": answered,
                  "failovers": dstats["failovers"],
                  "marked_dead": dstats["replicas_marked_dead"],
                  "replayed": recov["replayed"],
                  "caught_up": recov["caught_up"]},
        "flags": {"multiprocess_flat_identity": mp_flat_identity,
                  "multiprocess_zero_dropped": mp_zero_dropped,
                  "multiprocess_recovery_consistent": mp_recovery_consistent,
                  "multiprocess_speedup_ok": speedup_ok},
    }


def _shm_vs_socket_section(cfg, serve_cfg, data, queries, fd, fi,
                           workers: int, batch: int, smoke: bool,
                           root: str, key) -> dict:
    """Section 7: the slab fast path vs the socket path, same topology.

    ``shm_threshold_bytes=None`` disables staging entirely (every payload
    rides inline on AF_UNIX); 64 stages everything.  The counter deltas
    are snapshotted around the timed window only — boot/init traffic
    (key material, seed handshakes) legitimately rides the socket."""
    from repro.cluster import shm as shm_mod

    cores = len(os.sched_getaffinity(0))
    rng = np.random.default_rng(13)
    n_rows = batch * (6 if smoke else 16)
    rows = (rng.integers(0, 32, (n_rows, data.shape[1])) * 2).astype(np.int32)
    key_ = key

    def build(threshold, tag):
        return ClusterRouter(
            cfg, serve_cfg,
            ClusterConfig(num_shards=workers, num_replicas=1,
                          hedge_ms=60000.0, wal_fsync=False,
                          cache_capacity=0, transport="process",
                          pipeline_depth=4,
                          max_queue_depth=max(4096, n_rows),
                          shm_threshold_bytes=threshold, shm_slots=32),
            data, root + tag, key=key_)

    sock = build(None, "-shm-off")
    sock.query(queries[:batch])                     # warm compile paths
    socket_qps = _throughput_qps(sock, rows, batch)
    sock.close()

    shm_r = build(64, "-shm-on")
    sd, si = shm_r.query(queries)
    shm_identity = bool(np.array_equal(sd, fd) and np.array_equal(si, fi))
    before = shm_mod.wire_counters()
    shm_qps = _throughput_qps(shm_r, rows, batch)
    after = shm_mod.wire_counters()
    shm_r.close()
    delta = {k: int(after.get(k, 0) - before.get(k, 0))
             for k in set(before) | set(after)}
    socket_payload = (delta.get("socket_payload_tx_bytes", 0)
                      + delta.get("socket_payload_rx_bytes", 0))
    zero_copy = bool(socket_payload == 0
                     and delta.get("shm_stage_fallbacks", 0) == 0
                     and delta.get("shm_payload_tx_bytes", 0) > 0
                     and delta.get("shm_payload_rx_bytes", 0) > 0)
    speedup = shm_qps / max(socket_qps, 1e-9)
    gate_eligible = bool(cores >= 4)
    for tag in ("-shm-off", "-shm-on"):
        shutil.rmtree(root + tag, ignore_errors=True)
    return {
        "workers": workers,
        "cores": cores,
        "socket_qps": round(socket_qps, 1),
        "shm_qps": round(shm_qps, 1),
        "speedup": round(speedup, 2),
        "speedup_gate_eligible": gate_eligible,
        "query_phase_counter_deltas": {k: v for k, v in sorted(delta.items())
                                       if v},
        "flags": {"shm_flat_identity": shm_identity,
                  "shm_zero_socket_payload": zero_copy,
                  "shm_speedup_ok": bool((not gate_eligible)
                                         or speedup >= 1.3)},
    }


def _tcp_vs_unix_section(cfg, serve_cfg, data, queries, fd, fi,
                         workers: int, batch: int, smoke: bool, root: str,
                         key, unix_qps: float) -> dict:
    """Section 8: the loopback AF_INET grid vs section 6's AF_UNIX q/s."""
    rng = np.random.default_rng(17)
    n_rows = batch * (6 if smoke else 16)
    rows = (rng.integers(0, 32, (n_rows, data.shape[1])) * 2).astype(np.int32)
    t0 = time.perf_counter()
    tcp = ClusterRouter(
        cfg, serve_cfg,
        ClusterConfig(num_shards=workers, num_replicas=1, hedge_ms=60000.0,
                      wal_fsync=False, cache_capacity=0, transport="tcp",
                      pipeline_depth=4, max_queue_depth=max(4096, n_rows)),
        data, root + "-tcp", key=key)
    boot_ms = (time.perf_counter() - t0) * 1e3
    td, ti = tcp.query(queries)
    tcp_identity = bool(np.array_equal(td, fd) and np.array_equal(ti, fi))
    tcp_qps = _throughput_qps(tcp, rows, batch)
    tcp.close()
    shutil.rmtree(root + "-tcp", ignore_errors=True)
    return {"workers": workers,
            "boot_ms": round(boot_ms, 1),
            "unix_qps": round(unix_qps, 1),
            "tcp_qps": round(tcp_qps, 1),
            "tcp_vs_unix": round(tcp_qps / max(unix_qps, 1e-9), 2),
            "flags": {"tcp_flat_identity": tcp_identity}}


def main(smoke: bool = False, json_out: str = "BENCH_cluster.json",
         workers: int = None):
    t_start = time.time()
    if smoke:
        spec = ds.DatasetSpec("clu", n=2000, dim=16, universe=64,
                              num_clusters=8)
        cfg = IndexConfig(num_tables=4, num_hashes=8, width=24,
                          num_probes=20, candidate_cap=256, universe=64,
                          k=8, rerank_chunk=128)
        batch, n_queries, waves = 32, 64, 3
        shards, replicas = 2, 2
    else:
        spec = ds.DatasetSpec("clu", n=20000, dim=32, universe=64,
                              num_clusters=16)
        cfg = IndexConfig(num_tables=6, num_hashes=10, width=32,
                          num_probes=50, candidate_cap=512, universe=64,
                          k=10, rerank_chunk=512)
        batch, n_queries, waves = 64, 256, 4
        shards, replicas = 4, 2
    data = np.asarray(ds.make_dataset(spec))
    queries = np.asarray(ds.make_queries(spec, data, n_queries))
    key = jax.random.PRNGKey(0)
    serve_cfg = ServeConfig(batch_size=batch, delta_cap=256)
    root = tempfile.mkdtemp(prefix="cluster_bench_")

    t0 = time.perf_counter()
    router = ClusterRouter(
        cfg, serve_cfg,
        ClusterConfig(num_shards=shards, num_replicas=replicas,
                      hedge_ms=60000.0, wal_fsync=False, cache_capacity=512),
        data, root, key=key)
    init_ms = (time.perf_counter() - t0) * 1e3

    # -- 1. steady state: bit-identity vs flat + throughput ---------------
    state = build_index(cfg, key, jnp.asarray(data))
    fd, fi = map(np.asarray, query_index(cfg, state, jnp.asarray(queries)))
    cd, ci = router.query(queries)
    flat_identical = bool(np.array_equal(cd, fd) and np.array_equal(ci, fi))

    rng = np.random.default_rng(7)
    t0 = time.perf_counter()
    steady = 0
    for wave in range(waves):
        for size in (3, batch // 2, batch - 1, batch):
            q = (rng.integers(0, spec.universe // 2, (size, spec.dim)) * 2
                 ).astype(np.int32)
            d, i = router.query(q)
            steady += d.shape[0]
    steady_ms = (time.perf_counter() - t0) * 1e3

    # -- 2. chaos: unannounced replica failure mid-traffic ----------------
    mirror = AnnServingEngine(cfg, serve_cfg, dataset=jnp.asarray(data),
                              key=key)
    pts = (queries[: n_queries // 2] + 2).astype(np.int32)
    g_r, g_m = router.insert(pts), mirror.insert(pts)
    assert np.array_equal(g_r, g_m)
    submitted = answered = 0
    for wave in range(waves):
        if wave == 1:  # crash shard 0 replica 0 without telling the router
            router.replicas[0][0].fail_next_queries = 10 ** 9
        q = (queries + wave).astype(np.int32)
        d, i = router.query(q)
        submitted += q.shape[0]
        answered += int((i >= 0).all(axis=1).sum())
    zero_dropped = bool(answered == submitted)

    # -- 3. durability: WAL replay + catch-up, recovered replica serves ---
    router.replicas[0][0].alive = False          # the failing replica "dies"
    router.delete(g_r[::3])                      # mutations while it is down
    mirror.delete(g_m[::3])
    recov = router.recover_replica(0, 0)
    for r in range(1, replicas):                 # peers die: recovered serves
        router.kill_replica(0, r)
    rd, ri = router.query(queries)
    md, mi = mirror.query_batch(queries)
    recovery_consistent = bool(np.array_equal(rd, md)
                               and np.array_equal(ri, mi))

    # -- 4. hedging: slow replica, fast peer wins --------------------------
    hedge_router = ClusterRouter(
        cfg, serve_cfg,
        ClusterConfig(num_shards=2, num_replicas=2, hedge_ms=100.0,
                      wal_fsync=False),
        data[: spec.n // 2], root + "-hedge", key=key)
    hedge_router.query(queries[:batch])          # warm every compile path
    hs0 = hedge_router.summary()                 # cold compiles may hedge too
    hedge_router.replicas[0][0].slow_ms = 1000.0
    hedge_router._rr[0] = 0                      # slow replica is preferred
    t0 = time.perf_counter()
    hedge_router.query((queries[:batch] + 1).astype(np.int32))
    hedged_ms = (time.perf_counter() - t0) * 1e3
    hs = hedge_router.summary()
    hedged_reissues = hs["hedged_batches"] - hs0["hedged_batches"]
    hedge_wins = hs["hedge_wins"] - hs0["hedge_wins"]

    # -- 5. cache + admission ---------------------------------------------
    before = router.summary()["cache_misses"]
    router.query(queries)                        # repeat: all cache hits
    cache_hits = router.summary()["cache_hits"]
    cache_effective = bool(router.summary()["cache_misses"] == before)
    router.submit(queries[:8], deadline_ms=-1.0)  # already expired
    router.drain()
    shed = router.summary()["rejected_deadline"]

    # -- 6. multi-process serving over the RPC transport ------------------
    workers = workers if workers is not None else (2 if smoke else 4)
    mp = _multiprocess_section(cfg, serve_cfg, data, queries, fd, fi,
                               workers, batch, smoke, root)

    # -- 7. shm fast path vs socket, 8. tcp vs unix (DESIGN.md §13) --------
    shm_sec = _shm_vs_socket_section(cfg, serve_cfg, data, queries, fd, fi,
                                     workers, batch, smoke, root, key)
    tcp_sec = _tcp_vs_unix_section(cfg, serve_cfg, data, queries, fd, fi,
                                   workers, batch, smoke, root, key,
                                   unix_qps=mp["process_qps"])

    summary = router.summary()
    acceptance = {
        "cluster_matches_flat": flat_identical,
        "zero_dropped_queries_under_kill": zero_dropped,
        "recovery_consistent": recovery_consistent,
        "hedged_reissue_exercised": bool(hedged_reissues >= 1
                                         and hedge_wins >= 1),
        "cache_effective": cache_effective,
        "deadline_shedding_works": bool(shed >= 8),
        **mp["flags"],
        **shm_sec["flags"],
        **tcp_sec["flags"],
    }
    acceptance["ok"] = all(acceptance.values())
    result = {
        "bench": "cluster_serving_runtime",
        "backend": jax.default_backend(),
        "smoke": smoke,
        "config": {"n": spec.n, "dim": spec.dim, "shards": shards,
                   "replicas": replicas, "batch_size": batch,
                   "queries": n_queries, "k": cfg.k},
        "init_ms": round(init_ms, 1),
        "steady_queries": steady,
        "steady_qps": round(steady / (steady_ms / 1e3), 1),
        "chaos": {"submitted": submitted, "answered": answered,
                  "failovers": summary["failovers"],
                  "marked_dead": summary["replicas_marked_dead"]},
        "durability": {"replayed": recov["replayed"],
                       "caught_up": recov["caught_up"],
                       "recoveries": summary["recoveries"]},
        "hedging": {"hedge_ms": 100.0, "slow_ms": 1000.0,
                    "hedged_batches": hedged_reissues,
                    "hedge_wins": hedge_wins,
                    "hedged_batch_wall_ms": round(hedged_ms, 1)},
        "cache": {"hits": cache_hits,
                  "entries": summary["cache_entries"]},
        "admission": {"rejected_deadline": shed,
                      "rejected_queue_full":
                          summary["rejected_queue_full"]},
        "multiprocess": mp,
        "shm_vs_socket": shm_sec,
        "tcp_vs_unix": tcp_sec,
        "acceptance": acceptance,
        "wall_s": round(time.time() - t_start, 1),
    }
    router.close()
    hedge_router.close()
    shutil.rmtree(root, ignore_errors=True)
    shutil.rmtree(root + "-hedge", ignore_errors=True)
    with open(json_out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"cluster S={shards} R={replicas}: flat_identical={flat_identical} "
          f"zero_dropped={zero_dropped} recovery={recovery_consistent} "
          f"hedge_wins={hedge_wins} qps={result['steady_qps']} | "
          f"multiprocess W={mp['workers']} cores={mp['cores']} "
          f"{mp['inproc_qps']}->{mp['process_qps']} q/s "
          f"(x{mp['speedup']}, gate "
          f"{'on' if mp['speedup_gate_eligible'] else 'off'}) | "
          f"shm {shm_sec['socket_qps']}->{shm_sec['shm_qps']} q/s "
          f"(x{shm_sec['speedup']}, zero_socket="
          f"{shm_sec['flags']['shm_zero_socket_payload']}) | "
          f"tcp {tcp_sec['tcp_qps']} q/s "
          f"(x{tcp_sec['tcp_vs_unix']} vs unix) "
          f"-> {json_out}")
    if not acceptance["ok"]:
        raise SystemExit(f"cluster acceptance failed: {acceptance}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json-out", default="BENCH_cluster.json")
    ap.add_argument("--workers", type=int, default=None,
                    help="multiprocess section worker count "
                         "(default: 2 smoke / 4 full)")
    main(**vars(ap.parse_args()))
