"""End-to-end driver (the paper's kind of system): serve batched ANN requests
against a mutable segmented MP-RW-LSH index — live inserts/deletes with
watermark-triggered compaction — plus checkpoint + restart of the node.

  PYTHONPATH=src python examples/ann_serving.py
"""
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.core.baselines import brute_force_l1, recall
from repro.core.index import IndexConfig
from repro.core.segments import SegmentedIndex
from repro.data import ann_synthetic as ds
from repro.serve.engine import AnnServingEngine, ServeConfig


def main():
    spec = ds.DatasetSpec("serving", n=20000, dim=64, universe=128,
                          num_clusters=32)
    data = ds.make_dataset(spec)
    cfg = IndexConfig(num_tables=8, num_hashes=12, width=56, num_probes=200,
                      candidate_cap=128, universe=spec.universe, k=10)
    engine = AnnServingEngine(
        cfg, ServeConfig(batch_size=64, delta_cap=512, compact_watermark=0.6),
        jnp.asarray(data))

    # simulate request traffic in uneven bursts
    rng = np.random.default_rng(1)
    for burst in (30, 64, 100, 17):
        engine.submit(ds.make_queries(spec, data, burst, seed=int(rng.integers(1e6))))
        engine.drain()
        print(f"burst of {burst:3d} served; engine stats: {engine.summary()}")

    # quality check on a fresh batch
    q = ds.make_queries(spec, data, 64, seed=9)
    engine.submit(q)
    d, i = engine.drain()
    _, ti = brute_force_l1(jnp.asarray(data), jnp.asarray(q), 10)
    print("recall@10:", round(recall(i, np.asarray(ti)), 4))

    # live mutation: insert fresh points, query them, delete, verify gone
    new_pts = (rng.integers(0, spec.universe // 2, (400, spec.dim)) * 2
               ).astype(np.int32)
    gids = engine.insert(new_pts)          # crosses the watermark -> compacts
    engine.submit(new_pts[:64])
    d, i = engine.drain()
    hit = float((i[:, 0] == gids[:64]).mean())
    print(f"inserted {len(gids)} pts; self-hit@1 on inserts: {hit:.2f}; "
          f"stats: {engine.summary()}")
    assert hit == 1.0

    engine.delete(gids)
    engine.submit(new_pts[:64])
    d, i = engine.drain()
    assert not np.isin(i, gids).any(), "deleted points must never be returned"
    print("deleted inserts; none returned post-delete. "
          f"segments={engine.index.num_segments} "
          f"tombstones={engine.index.num_tombstones}")

    # checkpoint the node (payload = compacted IndexState + gids so every
    # acknowledged insert/delete survives), simulate a crash, restore,
    # re-serve
    payload = engine.checkpoint_payload()
    engine.submit(q)
    d, i = engine.drain()
    mgr = CheckpointManager("/tmp/repro_serving_ckpt", keep=1)
    mgr.save(1, payload)
    r_state, r_gids, r_next = mgr.restore(1, payload)
    node = SegmentedIndex.from_checkpoint(cfg, r_state, r_gids, r_next)
    d2, i2 = node.query(jnp.asarray(q))
    same = bool((np.asarray(d2) == d).all()) and bool((np.asarray(i2) == i).all())
    print("restored-node results identical:", same)
    assert same


if __name__ == "__main__":
    main()
