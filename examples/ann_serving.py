"""End-to-end driver (the paper's kind of system): serve batched ANN requests
against an MP-RW-LSH index, with checkpoint + restart of the serving node.

  PYTHONPATH=src python examples/ann_serving.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.core.baselines import brute_force_l1, recall
from repro.core.index import IndexConfig, query_index
from repro.data import ann_synthetic as ds
from repro.serve.engine import AnnServingEngine, ServeConfig


def main():
    spec = ds.DatasetSpec("serving", n=20000, dim=64, universe=128,
                          num_clusters=32)
    data = ds.make_dataset(spec)
    cfg = IndexConfig(num_tables=8, num_hashes=12, width=56, num_probes=200,
                      candidate_cap=128, universe=spec.universe, k=10)
    engine = AnnServingEngine(cfg, ServeConfig(batch_size=64),
                              jnp.asarray(data))

    # simulate request traffic in uneven bursts
    total = 0
    rng = np.random.default_rng(1)
    for burst in (30, 64, 100, 17):
        engine.submit(ds.make_queries(spec, data, burst, seed=int(rng.integers(1e6))))
        d, i = engine.drain()
        total += burst
        print(f"burst of {burst:3d} served; engine stats: {engine.summary()}")

    # quality check on a fresh batch
    q = ds.make_queries(spec, data, 64, seed=9)
    engine.submit(q)
    d, i = engine.drain()
    _, ti = brute_force_l1(jnp.asarray(data), jnp.asarray(q), 10)
    print("recall@10:", round(recall(i, np.asarray(ti)), 4))

    # checkpoint the node state, simulate a crash, restore, re-serve
    mgr = CheckpointManager("/tmp/repro_serving_ckpt", keep=1)
    mgr.save(1, engine.state)
    restored = mgr.restore(1, engine.state)
    d2, i2 = query_index(cfg, restored, jnp.asarray(q))
    same = bool((np.asarray(d2) == d).all())
    print("restored-node results identical:", same)
    assert same


if __name__ == "__main__":
    main()
