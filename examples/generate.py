"""Greedy generation with the decode path (KV/SSM caches), any architecture.

  PYTHONPATH=src python examples/generate.py --arch mamba2-370m --steps 24
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    if cfg.kind == "encdec":
        raise SystemExit("use the decoder-only/ssm archs for this example")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    max_len = args.steps + 8
    caches = M.make_caches(cfg, args.batch, max_len, jnp.float32)

    step = jax.jit(lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos))
    tok = jnp.full((args.batch, 1), 7, jnp.int32)
    out = [tok]
    for i in range(args.steps):
        logits, caches = step(params, caches, tok, jnp.int32(i))
        tok = jnp.argmax(logits[..., :cfg.vocab], axis=-1).astype(jnp.int32)
        out.append(tok)
    seq = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} generated {seq.shape}:")
    for row in seq:
        print(" ", row.tolist())


if __name__ == "__main__":
    main()
