"""Retrieval-augmented LM serving: every assigned architecture can act as the
embedding producer for an MP-RW-LSH memory (kNN-LM style).

Pipeline: prompt -> model hidden state (mean-pooled) -> paper Sect. 3.2
normalization (shift/scale/round-to-even) -> MP-RW-LSH query -> neighbor ids.

  PYTHONPATH=src python examples/retrieval_augmented_lm.py --arch smollm-360m
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.baselines import brute_force_l1, recall
from repro.core.index import IndexConfig, build_index, query_index
from repro.data.normalize import fit_normalizer
from repro.models import model as M
from repro.models import transformer as tf


def embed(params, cfg, tokens):
    """Mean-pooled final hidden state as the retrieval embedding."""
    x = params["embed"][tokens] * jnp.sqrt(cfg.d_model)
    pos = jnp.broadcast_to(jnp.arange(tokens.shape[1], dtype=jnp.int32)[None],
                           tokens.shape)
    if cfg.kind == "hybrid":
        h, _, _ = tf.hybrid_stack(params, cfg, x, positions=pos)
    elif cfg.kind == "encdec":
        h = tf.encoder_stack(
            params, cfg, jnp.repeat(x, 1, axis=1))  # encoder as embedder
    else:
        h, _, _ = tf.decoder_stack(params, cfg, x, positions=pos)
    return h.mean(axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--memory-size", type=int, default=4096)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    # 1. Build a "memory" of passage embeddings.
    mem_tokens = rng.integers(1, cfg.vocab, (args.memory_size, 16)).astype(np.int32)
    embs = np.asarray(jax.jit(lambda t: embed(params, cfg, t))(jnp.asarray(mem_tokens)))
    print("memory embeddings:", embs.shape)

    # 2. Normalize to even ints (paper Sect. 3.2) and index with MP-RW-LSH.
    norm = fit_normalizer(embs, target_universe=512)
    mem = norm.apply(embs)
    icfg = IndexConfig(num_tables=6, num_hashes=10, width=96, num_probes=100,
                       candidate_cap=64, universe=512, k=5)
    state = build_index(icfg, jax.random.PRNGKey(1), jnp.asarray(mem))

    # 3. Queries = perturbed copies of some passages (near-duplicates).
    q_idx = rng.integers(0, args.memory_size, 32)
    q_tokens = mem_tokens[q_idx].copy()
    q_tokens[:, -2:] = rng.integers(1, cfg.vocab, (32, 2))  # small edit
    q_embs = np.asarray(jax.jit(lambda t: embed(params, cfg, t))(jnp.asarray(q_tokens)))
    q = norm.apply(q_embs)

    d, i = query_index(icfg, state, jnp.asarray(q))
    top1 = np.asarray(i[:, 0])
    hit = float((top1 == q_idx).mean())
    td, ti = brute_force_l1(jnp.asarray(mem), jnp.asarray(q), 5)
    r = recall(np.asarray(i), np.asarray(ti))
    print(f"arch={cfg.name}: top-1 source-passage hit-rate={hit:.3f} "
          f"recall@5 vs exact-L1={r:.3f}")


if __name__ == "__main__":
    main()
