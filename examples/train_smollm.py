"""Train a reduced smollm for a few hundred steps with checkpoint/restart
(deliverable b, training flavor).  Thin wrapper over the launcher.

  PYTHONPATH=src python examples/train_smollm.py
"""
from repro.launch.train import main as train_main


if __name__ == "__main__":
    train_main([
        "--arch", "smollm-360m", "--reduced",
        "--steps", "200", "--batch", "8", "--seq", "128",
        "--lr", "3e-3", "--ckpt-dir", "/tmp/repro_train_ckpt",
        "--ckpt-every", "50", "--resume",
    ])
