"""Quickstart: build an MP-RW-LSH index, query it, verify against brute force.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import brute_force_l1, overall_ratio, recall
from repro.core.index import IndexConfig, build_index, query_index
from repro.data import ann_synthetic as ds
from repro.data.normalize import normalize_even


def main():
    # 1. Any real-valued dataset -> nonnegative even ints (paper Sect. 3.2).
    raw = np.random.default_rng(0).normal(size=(5000, 32)) * 3.0
    data = normalize_even(raw, target_universe=256)
    print("normalized:", data.shape, data.dtype, "universe<=", data.max())

    # 2. A clustered benchmark dataset + queries with known near neighbors.
    spec = ds.DatasetSpec("quickstart", n=20000, dim=64, universe=128,
                          num_clusters=32)
    data = ds.make_dataset(spec)
    queries = ds.make_queries(spec, data, 64)

    # 3. Build: L tables x M random-walk hashes, sorted-key layout.
    cfg = IndexConfig(num_tables=8, num_hashes=12, width=56, num_probes=200,
                      candidate_cap=128, universe=spec.universe, k=10)
    state = build_index(cfg, jax.random.PRNGKey(0), jnp.asarray(data))
    print(f"index: {cfg.num_tables} tables, {cfg.num_hashes} hashes/table, "
          f"T={cfg.num_probes} probes (template, paper refinement 3)")

    # 4. Query (batched, jit) + exact L1 rerank.
    d, i = query_index(cfg, state, jnp.asarray(queries))

    # 5. Quality vs exact brute force.
    td, ti = brute_force_l1(jnp.asarray(data), jnp.asarray(queries), 10)
    print("recall@10 :", round(recall(np.asarray(i), np.asarray(ti)), 4))
    print("overall ratio:", round(overall_ratio(np.asarray(d), np.asarray(td)), 4))


if __name__ == "__main__":
    main()
