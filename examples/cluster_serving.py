"""End-to-end cluster serving walkthrough (DESIGN.md §7): a sharded,
replicated, WAL-durable MP-RW-LSH cluster surviving a replica crash with
zero dropped queries, recovering it from snapshot + WAL replay, and serving
bit-identical answers throughout — then one traced query (DESIGN.md §12)
rendered as a Chrome trace you can open in Perfetto.

  PYTHONPATH=src python examples/cluster_serving.py
"""
import json
import os
import shutil
import tempfile

import numpy as np

from repro.cluster import ClusterConfig, ClusterRouter
from repro.core.index import IndexConfig
from repro.data import ann_synthetic as ds
from repro.obs import trace as obs_trace
from repro.obs.render import check_spans, load_spans, to_chrome
from repro.serve.engine import ServeConfig


def main():
    spec = ds.DatasetSpec("cluster-demo", n=8000, dim=32, universe=64,
                          num_clusters=16)
    data = np.asarray(ds.make_dataset(spec))
    cfg = IndexConfig(num_tables=6, num_hashes=10, width=28, num_probes=40,
                      candidate_cap=256, universe=spec.universe, k=10,
                      rerank_chunk=512)
    root = tempfile.mkdtemp(prefix="cluster_demo_")
    router = ClusterRouter(
        cfg, ServeConfig(batch_size=64),
        ClusterConfig(num_shards=2, num_replicas=2, hedge_ms=5000.0),
        data, root)
    print(f"cluster up: 2 shards x 2 replicas over n={spec.n} "
          f"(WAL+snapshots under {root})")

    queries = np.asarray(ds.make_queries(spec, data, 96))
    d0, i0 = router.query(queries)
    print(f"served {len(queries)} queries; "
          f"top-1 gid of q0 = {int(i0[0, 0])}")

    # live mutations are WAL'd on every replica before being acknowledged
    new_pts = (np.random.default_rng(1).integers(
        0, spec.universe // 2, (200, spec.dim)) * 2).astype(np.int32)
    gids = router.insert(new_pts)
    d, i = router.query(new_pts[:32])
    assert (i[:, 0] == gids[:32]).all(), "inserts must be their own top-1"
    print(f"inserted {len(gids)} points; self-hit@1 on inserts: 1.00")

    # a replica starts failing unannounced; traffic is failed over
    base_d, base_i = router.query(queries)       # post-insert baseline
    router.replicas[0][0].fail_next_queries = 10 ** 9
    router.clear_cache()                         # force real dispatches
    d1, i1 = router.query(queries)
    s = router.summary()
    assert np.array_equal(i1, base_i) and np.array_equal(d1, base_d)
    print(f"replica 0/0 crashed mid-traffic: {s['failovers']} failovers, "
          f"0 dropped queries, answers bit-identical")

    # mutations keep flowing while it is down, then it recovers:
    # snapshot restore + WAL replay + catch-up from its live peer
    router.replicas[0][0].alive = False
    router.delete(gids[:50])
    info = router.recover_replica(0, 0)
    print(f"replica recovered: replayed {info['replayed']} WAL records, "
          f"caught up {info['caught_up']} from peer")

    post_d, post_i = router.query(queries)       # post-delete baseline
    router.kill_replica(0, 1)          # force the recovered replica to serve
    router.clear_cache()
    d2, i2 = router.query(queries)
    assert np.array_equal(i2, post_i) and np.array_equal(d2, post_d)
    print("recovered replica serves; answers unchanged. summary:")
    s = router.summary()
    print({k: s[k] for k in ("queries", "batches", "failovers", "recoveries",
                             "cache_hits", "replicas_marked_dead")})
    # the same counters, as one mergeable cluster roll-up (DESIGN.md §12):
    # per-replica registry snapshots folded order-independently, with the
    # engine batch latency as exact-bound histogram quantiles
    cm = s["cluster_metrics"]
    print(f"cluster roll-up: {cm['counters']['batches']} engine batches, "
          f"p99 batch <= {cm['histograms']['batch_ms']['p99_ms']:.2f} ms; "
          f"router dispatch p50 <= {s['dispatch_ms']['p50_ms']:.2f} ms")

    # -- traced query (DESIGN.md §12) -------------------------------------
    # REPRO_TRACE=1 turns the span machinery on (off, every span call is a
    # shared no-op); one cache-bypassed query then leaves its whole tree —
    # cluster_batch -> fanout -> shard_query -> replica_query ->
    # engine_batch -> phase_a/phase_b_rerank/merge — as JSONL in
    # REPRO_TRACE_DIR, rendered here into Chrome trace-event JSON.
    trace_dir = os.path.join(root, "trace")
    os.environ["REPRO_TRACE"] = "1"
    os.environ["REPRO_TRACE_DIR"] = trace_dir
    try:
        router.clear_cache()
        router.query(queries[:32])
    finally:
        del os.environ["REPRO_TRACE"]
    obs_trace.flush()
    spans = load_spans(trace_dir)
    report = check_spans(spans)
    out_path = os.path.join(trace_dir, "trace.json")
    with open(out_path, "w", encoding="utf-8") as f:
        json.dump(to_chrome(spans), f)
    slowest = max((r for r in spans if r["name"] == "replica_query"),
                  key=lambda r: r["dur"], default=None)
    print(f"traced query: {report['records']} spans on "
          f"{report['traces']} trace(s), schema ok={report['ok']}; "
          f"slowest replica_query {slowest['dur'] / 1000:.2f} ms "
          f"(shard {slowest['args']['shard']})")
    print(f"open {out_path} in https://ui.perfetto.dev to see the tree")
    router.close()
    shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
